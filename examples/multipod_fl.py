"""Multi-pod FL aggregation with FairEnergy compression (shard_map).

Each pod is an FL silo; the cross-pod update exchange is top-k sparsified
to the controller's gamma. Two exchange formats:
  * dense-masked all-reduce (simple, but still moves S bytes), and
  * SPARSE (values+indices all-gather) — the paper's gamma*S + I payload
    as real ICI bytes: ~62-92% fewer wire bytes at gamma in [0.1, 0.25]
    with int8 values + int16 indices (EXPERIMENTS.md §Perf-3).

Runs anywhere (8 host placeholder devices = 2 pods x 2x2).

  PYTHONPATH=src python examples/multipod_fl.py
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import re

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.fl.collectives import make_fl_allreduce, make_sparse_fl_allreduce

mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
gamma = 0.25
n = 1 << 18

rng = np.random.default_rng(0)
vec = jax.device_put(jnp.asarray(rng.normal(size=n).astype(np.float32)),
                     NamedSharding(mesh, P(("data", "model"))))

DT = {"f32": 4, "bf16": 2, "s32": 4, "s8": 1, "s16": 2, "u16": 2, "u32": 4, "pred": 1, "u8": 1}


def coll_bytes(fn):
    text = jax.jit(fn).lower(vec).compile().as_text()
    total = 0
    for m in re.finditer(r"=\s*((?:\([^)]*\))|(?:\w+\[[^\]]*\]\S*))\s*"
                         r"(all-reduce|all-gather|reduce-scatter|all-to-all)"
                         r"(?!-done)(?:-start)?\(", text):
        for mm in re.finditer(r"(f32|bf16|s32|s8|s16|u16|u32|u8|pred)\[([0-9,]*)\]",
                              m.group(1)):
            nn = 1
            for d in mm.group(2).split(","):
                if d:
                    nn *= int(d)
            total += nn * DT[mm.group(1)]
    return total


dense = make_fl_allreduce(mesh, gamma)
sparse = make_sparse_fl_allreduce(mesh, gamma, quantize=True)
b_dense, b_sparse = coll_bytes(dense), coll_bytes(sparse)
agg_d, agg_s = dense(vec), sparse(vec)
rel = float(jnp.abs(agg_s - agg_d).max() / jnp.abs(agg_d).max())
print(f"update: {n} coords, gamma={gamma}")
print(f"dense-masked all-reduce : {b_dense/2**20:.2f} MiB collective result bytes")
print(f"sparse int8+int16 gather: {b_sparse/2**20:.2f} MiB ({1-b_sparse/b_dense:.0%} fewer)")
print(f"aggregate rel. error from int8 quantization: {rel:.4f}")
